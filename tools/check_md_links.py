"""Markdown link checker for the repo docs.

Walks the given markdown files (default: README.md + docs/*.md),
extracts inline links/images, and verifies that every *local* target
exists relative to the linking file (external http(s)/mailto links are
skipped — CI must not depend on the network).  Anchors are stripped;
a `#fragment`-only link is checked against the file's own headings.

    python tools/check_md_links.py [files...]

Exit 0 when every local target resolves, 1 otherwise (one line per
broken link).  `tests/test_docs.py` runs the same check in tier-1.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# inline [text](target) links and images; reference-style links are
# not used in this repo's docs
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def default_files():
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(path: Path) -> list[str]:
    text = path.read_text()
    anchors = {_slug(h) for h in _HEADING.findall(text)}
    errors = []
    for target in _LINK.findall(_CODE_FENCE.sub("", text)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        if not base:
            if frag and _slug(frag) not in anchors:
                errors.append(f"{path.relative_to(REPO)}: broken "
                              f"anchor #{frag}")
            continue
        resolved = (path.parent / base).resolve()
        if not resolved.exists():
            errors.append(f"{path.relative_to(REPO)}: broken link "
                          f"{target} -> {resolved}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    files = [Path(a).resolve() for a in argv] or default_files()
    errors = []
    for f in files:
        errors += check_file(f)
    for e in errors:
        print(f"FAIL  {e}")
    if not errors:
        print(f"markdown link check: {len(files)} file(s) OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
