"""Paper Table V + Sec III-C analogue — end-to-end HRL agent inference.

FPS of the full E2HRL agent (Q-FC and Q-LSTM variants) per precision
on this host's SIMD units, plus analytic GOP/frame, energy proxy, and
the learner->actor sync payload (Q-Actor's communication win).

Paper reference points: FC-HRL 1110 FPS fp32 -> Q-FC 2835 FPS (2.55x);
LSTM-HRL 435 -> Q-LSTM 924 (2.12x); CPU 6.2 ms fp32, 2.6x int8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, energy_proxy_mj, timeit
from repro.configs.e2hrl import CONFIG, CONFIG_LSTM
from repro.core.policy import get_policy
from repro.core.quantizer import quantize_params, quantized_nbytes
from repro.models import hrl
from repro.nn.module import unbox

BATCH = 512         # frames per call: amortized-steady-state serving

# TPU v5e projection: the agent is tiny, so serving is weight+activation
# bandwidth bound; per-precision the roofline FPS scales with
# bytes-moved (4x fewer at int8) until the 2x int8 MXU compute cap.
PEAK = {8: 394e12, 16: 197e12, 32: 197e12 / 8}
HBM = 819e9


def agent_macs(cfg) -> float:
    """Analytic MACs per frame (conv + fc + subgoal + heads)."""
    h, w, c = cfg.obs_shape
    macs = 0.0
    cin = c
    for cout in cfg.conv_channels:
        h, w = (h + 1) // 2, (w + 1) // 2
        macs += h * w * cout * cin * cfg.conv_kernel ** 2
        cin = cout
    flat = h * w * cin
    macs += flat * cfg.embed_dim
    if cfg.subgoal_kind == "fc":
        macs += cfg.embed_dim * cfg.subgoal_hidden \
            + cfg.subgoal_hidden * cfg.subgoal_dim
    else:
        macs += 4 * (cfg.embed_dim + cfg.subgoal_hidden) \
            * cfg.subgoal_hidden + cfg.subgoal_hidden * cfg.subgoal_dim
    macs += (cfg.embed_dim + cfg.subgoal_dim) * (cfg.n_actions + 1)
    return macs


def run():
    key = jax.random.PRNGKey(0)
    for cfg, label in [(CONFIG, "Q-FC"), (CONFIG_LSTM, "Q-LSTM")]:
        params_fp = unbox(hrl.init(key, cfg))
        obs = jax.random.uniform(
            key, (BATCH,) + ((4,) if cfg.subgoal_kind == "lstm"
                             else ()) + cfg.obs_shape)
        macs = agent_macs(cfg) * (4 if cfg.subgoal_kind == "lstm" else 1)

        base_fps = None
        for pol_name, bits in [("fxp32", 32), ("fxp16", 16), ("fxp8", 8)]:
            policy = get_policy(pol_name)
            params = (quantize_params(params_fp, policy)
                      if policy.quantized_w else params_fp)

            def step(p, o, pol=policy):
                logits, value, _ = hrl.apply(p, o, cfg, pol)
                return jnp.argmax(logits, -1)

            f = jax.jit(step)
            sec = timeit(f, params, obs)
            fps = BATCH / sec
            if bits == 32:
                base_fps = fps
            stored, fp32b = quantized_nbytes(params)
            e = energy_proxy_mj(macs, bits, stored) / 1  # per frame
            # TPU roofline projection per frame: weights + activations
            # traffic at this precision vs the MXU rate
            act_bytes = BATCH * 32 * 32 * 3 * (bits // 8)
            t_mem = (stored + act_bytes) / HBM
            t_cmp = 2 * macs * BATCH / PEAK[bits]
            tpu_fps = BATCH / max(t_mem, t_cmp)
            emit("arch", f"{label}_{pol_name}",
                 fps=round(fps),
                 ms_per_frame=round(1e3 * sec / BATCH, 3),
                 gop_frame=round(2 * macs / 1e9, 4),
                 gops=round(2 * macs * fps / 1e9, 2),
                 weight_bytes=stored,
                 energy_proxy_mj_frame=round(e, 4),
                 speedup_vs_fxp32=round(fps / base_fps, 2),
                 tpu_roofline_fps=f"{tpu_fps:.2e}")

        # Q-Actor sync payload per weight broadcast
        for bits in (32, 16, 8):
            from repro.rl.actor_learner import pack_weights, sync_bytes
            packed = pack_weights(params_fp, bits)
            payload, fp32b = sync_bytes(packed)
            emit("arch", f"{label}_sync_{bits}b",
                 payload_bytes=payload,
                 reduction_vs_fp32=round(fp32b / payload, 2))
