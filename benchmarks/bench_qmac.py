"""Paper Tables II & III analogue — Q-MAC per-precision throughput.

The FPGA tables report LUT/FF/power per precision; the architecture-
neutral content is the *precision->throughput/energy scaling law* of
the multi-precision MAC fabric.  We measure:

  * CPU wall-clock GOP/s of the quantized matmul per FxP mode (XLA
    int8/int16/fp32 paths — the SIMD units the paper's CPU baseline
    uses via Arm NEON are here AVX);
  * bytes moved per op (the energy proxy driver);
  * TPU-projected GOP/s from roofline terms (197/394 TOPS peaks);
  * energy-efficiency proxy (GOPS/W-equivalent via pJ/op model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (PJ_PER_MAC, emit, energy_proxy_mj,
                               timeit)
from repro.core.policy import get_policy
from repro.core.qmatmul import q_matmul

M = N = K = 1024
MACS = M * N * K


def run():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(key, (K, N))

    results = {}
    for name, bits in [("fxp8", 8), ("fxp16", 16), ("fxp32", 32)]:
        policy = get_policy(name)
        f = jax.jit(lambda x, w, p=policy: q_matmul(x, w, p))
        sec = timeit(f, x, w)
        gops = 2 * MACS / sec / 1e9
        # weight bytes/op dominate at serving batch sizes
        wbytes = K * N * (bits // 8)
        abytes = (M * K + M * N) * 4
        e_mj = energy_proxy_mj(MACS, bits, wbytes + abytes)
        results[bits] = gops
        emit("qmac", f"{name}",
             cpu_gops=round(gops, 2),
             sec_per_matmul=round(sec * 1e3, 3),
             weight_bytes=wbytes,
             pj_per_mac=PJ_PER_MAC[bits],
             energy_mj=round(e_mj, 4),
             tpu_peak_gops=394_000 if bits == 8 else
             (197_000 if bits == 16 else 24_600))

    # the paper's headline: throughput scaling vs the 32-bit baseline
    emit("qmac", "scaling_vs_fxp32",
         fxp8=round(results[8] / results[32], 2),
         fxp16=round(results[16] / results[32], 2),
         paper_simd_lanes="16/4/1",
         paper_cpu_speedup="2.6x/1.4x (paper Sec III-C)")
