"""Beyond-paper table — the Q-MAC/V-ACT fabric on LM workloads.

The paper's Sec. IV claims the compute blocks generalize to DNNs; here
we measure the generalization on a real (reduced) LM: per-precision
train-step and decode-step wall clock + PTQ weight footprint + int8 KV
cache footprint, on the host CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.registry import get_arch
from repro.core.policy import get_policy
from repro.core.quantizer import quantize_params, quantized_nbytes
from repro.launch.steps import make_train_step
from repro.models.registry import model_for
from repro.nn.module import unbox
from repro.optim import adamw_init

B, S = 4, 128


def run():
    cfg = get_arch("tinyllama-1.1b").reduced().replace(
        d_model=256, d_ff=512, n_layers=4, vocab=1024)
    model = model_for(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    opt = adamw_init(params)

    base = None
    for pol in ("fp32", "w8a8"):
        policy = get_policy(pol)
        step = jax.jit(make_train_step(cfg, None, policy))
        sec = timeit(step, params, opt, batch, iters=5)
        if base is None:
            base = sec
        emit("lm", f"train_{pol}", ms=round(sec * 1e3, 1),
             tok_s=round(B * S / sec),
             speedup=round(base / sec, 2))

    # serving: PTQ + int8 KV decode
    for pol in ("fp32", "w8a8kv8"):
        policy = get_policy(pol)
        p = quantize_params(params, policy) if policy.quantized_w \
            else params
        stored, fp32b = quantized_nbytes(p)
        logits, caches = jax.jit(
            lambda p, t, pol=policy: model.prefill(p, t, cfg, pol,
                                                   pol.kv_bits))(p, toks)
        kv_bytes = sum(l.size * l.dtype.itemsize
                       for l in jax.tree.leaves(caches))

        def dec(p, tok, caches, pol=policy):
            return model.decode_step(p, tok, caches,
                                     jnp.asarray(S, jnp.int32), cfg,
                                     pol, pol.kv_bits)

        f = jax.jit(dec)
        tok = jnp.zeros((B, 1), jnp.int32)
        sec = timeit(f, p, tok, caches, iters=5)
        emit("lm", f"decode_{pol}",
             ms_per_token=round(sec * 1e3, 2),
             weight_mib=round(stored / 2**20, 2),
             weight_vs_fp32=round(fp32b / stored, 2),
             kv_cache_mib=round(kv_bytes / 2**20, 2))
