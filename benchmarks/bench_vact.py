"""Paper Table IV analogue — V-ACT: one reconfigurable activation unit.

Reported per (AF x precision): CORDIC iteration count (the paper's
(3n/8+1) low-latency schedule), max error vs the fp oracle, CPU
wall-clock, and the fused-vs-unfused HBM traffic that motivates fusing
quantize->AF->requantize into one pass (the unit's architectural win).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.policy import cordic_iterations, get_policy
from repro.core.vact import activation

SHAPE = (256, 4096)


def run():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, SHAPE) * 3.0

    oracle = {"sigmoid": jax.nn.sigmoid, "tanh": jnp.tanh,
              "relu": jax.nn.relu,
              "softmax": lambda v: jax.nn.softmax(v, axis=-1)}

    for pol_name in ("fxp8", "fxp16", "fxp32"):
        policy = get_policy(pol_name).replace(act_backend="cordic")
        iters = cordic_iterations(policy)
        for kind in ("sigmoid", "tanh", "relu", "softmax"):
            f = jax.jit(lambda v, k=kind, p=policy: activation(v, k, p))
            sec = timeit(f, x)
            ref = oracle[kind](x)
            err = float(jnp.max(jnp.abs(f(x) - ref)))
            emit("vact", f"{kind}_{pol_name}",
                 cordic_iters=iters,
                 max_err=round(err, 5),
                 us=round(sec * 1e6, 1),
                 gop_s=round(x.size / sec / 1e9, 2))

    # fused quantized-activation traffic model: unfused writes the fp
    # intermediate to HBM and reads it back; fused keeps it in VMEM
    n = int(np.prod(SHAPE))
    unfused = n * (4 + 4 + 4 + 1)      # read fp32, write fp32, read, write i8
    fused = n * (4 + 1)                # read fp32, write int8
    emit("vact", "fusion_traffic",
         unfused_bytes=unfused, fused_bytes=fused,
         saving=f"{unfused / fused:.1f}x")
