"""Pixel-pipeline throughput sweep: env x frame_stack x precision x net.

For each pixel env (catch, keydoor) the quantized actor fleet rolls
through ``collect_sharded`` with the observation stack the training
launch paths actually use:

  * ``net=conv`` — running-normalize + frame_stack(k) feeding the
    Q-Conv stem (the paper's raw-image path, no flatten);
  * ``net=mlp``  — the same stack flattened for the MLP actor (the
    historical baseline the conv stem replaces).

Each leg reports env-steps/s and the int8 weight-sync payload (MiB) —
the conv-stem counterpart of ``bench_env_throughput``'s MLP sweep, so
the quantized vision path is measured with the same instrument.

The ``pixel_stem`` table isolates the Q-Conv stem itself (the two
stride-2 conv blocks, no env stepping): the fake-quant XLA conv
(``backend=ref``) against the integer taps/Pallas path
(``backend=xla``/``pallas``) on the training stem shapes, in
conv-block applications per second (``convs_per_s``, a
``check_regression`` rate field — the integer path's win over the
fake-quant rows is baked into the committed baseline and gated).

Standalone:

    PYTHONPATH=src:. python -m benchmarks.bench_pixel_throughput \
        [--full] [--json out.json]

or via the orchestrator: ``python -m benchmarks.run --only pixel``.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from benchmarks.common import emit, timeit
from repro.core.policy import get_policy
from repro.launch.mesh import describe, make_host_mesh
from repro.nn.module import unbox
from repro.rl import init_envs
from repro.rl.actor_learner import collect_sharded, pack_weights, sync_bytes
from repro.rl.envs import make
from repro.rl.envs.spaces import head_dim
from repro.rl.envs.wrappers import flatten_observation, pixel_pipeline
from repro.rl.nets import (conv_ac_apply, conv_ac_init, mlp_ac_apply,
                           mlp_ac_init)

PIXEL_ENVS = ("catch", "keydoor")


def bench_one(env_name: str, policy_name: str, net: str, k: int,
              n_envs: int, rollout_len: int, n_dev: int = 1) -> float:
    base = pixel_pipeline(make(env_name), k)
    key = jax.random.PRNGKey(0)
    if net == "conv":
        env = base
        params = unbox(conv_ac_init(key, env.obs_shape,
                                    head_dim(env.action_space)))
        apply_fn = conv_ac_apply
    else:
        env = flatten_observation(base)
        params = unbox(mlp_ac_init(key, env.obs_shape[0],
                                   head_dim(env.action_space)))
        apply_fn = mlp_ac_apply
    policy = get_policy(policy_name) if policy_name != "fp32" else None
    packed = pack_weights(params, 8 if policy else 32)
    payload, fp32_eq = sync_bytes(packed)
    mesh = make_host_mesh(n_dev)
    est, obs = init_envs(env, jax.random.PRNGKey(1), n_envs, mesh=mesh)

    fn = jax.jit(lambda packed, key, est, obs: collect_sharded(
        packed, env, apply_fn, policy, key, est, obs, rollout_len, mesh))
    sec = timeit(fn, packed, jax.random.PRNGKey(2), est, obs,
                 warmup=1, iters=5)
    steps_per_s = n_envs * rollout_len / sec
    emit("pixel_throughput",
         f"{env_name}/k{k}/{policy_name}/{net}",
         env=env_name, policy=policy_name, net=net, frame_stack=k,
         n_envs=n_envs, rollout_len=rollout_len,
         steps_per_s=int(steps_per_s),
         sync_mib=round(payload / 2**20, 4),
         sync_fp32_mib=round(fp32_eq / 2**20, 4))
    return steps_per_s


# stem variants: the fake-quant XLA conv vs the integer qconv paths
# (repro.kernels.qconv).  The Pallas kernel leg only runs on a real
# TPU — interpreter-mode timings measure the interpreter, not the
# kernel — so the CI (CPU) baseline carries fakequant + int8 rows.
STEM_VARIANTS = {
    "fakequant": "ref",
    "int8": "xla",
    "pallas": "pallas",
}


def bench_stem(env_name: str, k: int, variant: str,
               n_envs: int) -> float:
    """Time the bare Q-Conv stem (both stride-2 blocks) at fxp8."""
    from repro.nn.conv import conv2d_init, qconv_block
    from repro.nn.module import unbox as _unbox
    from repro.rl.nets import CONV_CHANNELS, CONV_KERNEL

    pol = dataclasses.replace(get_policy("fxp8"),
                              backend=STEM_VARIANTS[variant])
    env = pixel_pipeline(make(env_name), k)
    h, w, _ = env.obs_shape
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n_envs, h, w, k))
    layers, c_in = [], k
    for i, c_out in enumerate(CONV_CHANNELS):
        layers.append(_unbox(conv2d_init(jax.random.fold_in(key, i),
                                         c_in, c_out, CONV_KERNEL)))
        c_in = c_out

    def stem(layers, x):
        for p in layers:
            x = qconv_block(p, x, stride=2, policy=pol)
        return x

    sec = timeit(jax.jit(stem), layers, x, warmup=2, iters=20)
    convs_per_s = n_envs * len(layers) / sec
    emit("pixel_stem", f"{env_name}/k{k}/{variant}",
         env=env_name, frame_stack=k, variant=variant, n_envs=n_envs,
         convs_per_s=int(convs_per_s),
         us_per_stem=round(sec * 1e6, 1))
    return convs_per_s


def run_stem(envs=PIXEL_ENVS, stacks=(1, 4), n_envs: int = 64):
    variants = ["fakequant", "int8"]
    if jax.default_backend() == "tpu":
        variants.append("pallas")
    for env_name in envs:
        for k in stacks:
            rates = {v: bench_stem(env_name, k, v, n_envs)
                     for v in variants}
            emit("pixel_stem_q_speedup", f"{env_name}/k{k}",
                 int8_vs_fakequant=round(rates["int8"]
                                         / rates["fakequant"], 2))


def run(fast: bool = True, n_envs: int = 0, rollout_len: int = 0,
        envs=PIXEL_ENVS, stacks=(1, 4)):
    n_envs = n_envs or (64 if fast else 256)
    rollout_len = rollout_len or (16 if fast else 64)
    print(f"{describe(make_host_mesh(1))}; n_envs={n_envs}, "
          f"rollout_len={rollout_len}, frame_stacks={list(stacks)}")
    for env_name in envs:
        for k in stacks:
            results = {}
            for policy_name in ("fp32", "fxp8"):
                for net in ("conv", "mlp"):
                    results[(policy_name, net)] = bench_one(
                        env_name, policy_name, net, k, n_envs,
                        rollout_len)
            for net in ("conv", "mlp"):
                emit("pixel_throughput_q_speedup",
                     f"{env_name}/k{k}/{net}",
                     fxp8_vs_fp32=round(results[("fxp8", net)]
                                        / results[("fp32", net)], 2))
    run_stem(envs=envs, stacks=stacks, n_envs=n_envs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--n-envs", type=int, default=0)
    ap.add_argument("--rollout-len", type=int, default=0)
    ap.add_argument("--envs", default=",".join(PIXEL_ENVS),
                    help="comma-separated subset of the pixel envs")
    ap.add_argument("--stacks", default="1,4",
                    help="comma-separated frame_stack depths")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None,
                    help="write the emit rows as JSON (CI gate input)")
    args = ap.parse_args(argv)
    run(fast=not args.full, n_envs=args.n_envs,
        rollout_len=args.rollout_len, envs=args.envs.split(","),
        stacks=[int(s) for s in args.stacks.split(",")])
    if args.csv:
        from benchmarks.common import dump_csv
        dump_csv(args.csv)
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
