"""Pixel-pipeline throughput sweep: env x frame_stack x precision x net.

For each pixel env (catch, keydoor) the quantized actor fleet rolls
through ``collect_sharded`` with the observation stack the training
launch paths actually use:

  * ``net=conv`` — running-normalize + frame_stack(k) feeding the
    Q-Conv stem (the paper's raw-image path, no flatten);
  * ``net=mlp``  — the same stack flattened for the MLP actor (the
    historical baseline the conv stem replaces).

Each leg reports env-steps/s and the int8 weight-sync payload (MiB) —
the conv-stem counterpart of ``bench_env_throughput``'s MLP sweep, so
the quantized vision path is measured with the same instrument.

Standalone:

    PYTHONPATH=src:. python -m benchmarks.bench_pixel_throughput \
        [--full] [--json out.json]

or via the orchestrator: ``python -m benchmarks.run --only pixel``.
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, timeit
from repro.core.policy import get_policy
from repro.launch.mesh import describe, make_host_mesh
from repro.nn.module import unbox
from repro.rl import init_envs
from repro.rl.actor_learner import collect_sharded, pack_weights, sync_bytes
from repro.rl.envs import make
from repro.rl.envs.spaces import head_dim
from repro.rl.envs.wrappers import flatten_observation, pixel_pipeline
from repro.rl.nets import (conv_ac_apply, conv_ac_init, mlp_ac_apply,
                           mlp_ac_init)

PIXEL_ENVS = ("catch", "keydoor")


def bench_one(env_name: str, policy_name: str, net: str, k: int,
              n_envs: int, rollout_len: int, n_dev: int = 1) -> float:
    base = pixel_pipeline(make(env_name), k)
    key = jax.random.PRNGKey(0)
    if net == "conv":
        env = base
        params = unbox(conv_ac_init(key, env.obs_shape,
                                    head_dim(env.action_space)))
        apply_fn = conv_ac_apply
    else:
        env = flatten_observation(base)
        params = unbox(mlp_ac_init(key, env.obs_shape[0],
                                   head_dim(env.action_space)))
        apply_fn = mlp_ac_apply
    policy = get_policy(policy_name) if policy_name != "fp32" else None
    packed = pack_weights(params, 8 if policy else 32)
    payload, fp32_eq = sync_bytes(packed)
    mesh = make_host_mesh(n_dev)
    est, obs = init_envs(env, jax.random.PRNGKey(1), n_envs, mesh=mesh)

    fn = jax.jit(lambda packed, key, est, obs: collect_sharded(
        packed, env, apply_fn, policy, key, est, obs, rollout_len, mesh))
    sec = timeit(fn, packed, jax.random.PRNGKey(2), est, obs,
                 warmup=1, iters=5)
    steps_per_s = n_envs * rollout_len / sec
    emit("pixel_throughput",
         f"{env_name}/k{k}/{policy_name}/{net}",
         env=env_name, policy=policy_name, net=net, frame_stack=k,
         n_envs=n_envs, rollout_len=rollout_len,
         steps_per_s=int(steps_per_s),
         sync_mib=round(payload / 2**20, 4),
         sync_fp32_mib=round(fp32_eq / 2**20, 4))
    return steps_per_s


def run(fast: bool = True, n_envs: int = 0, rollout_len: int = 0,
        envs=PIXEL_ENVS, stacks=(1, 4)):
    n_envs = n_envs or (64 if fast else 256)
    rollout_len = rollout_len or (16 if fast else 64)
    print(f"{describe(make_host_mesh(1))}; n_envs={n_envs}, "
          f"rollout_len={rollout_len}, frame_stacks={list(stacks)}")
    for env_name in envs:
        for k in stacks:
            results = {}
            for policy_name in ("fp32", "fxp8"):
                for net in ("conv", "mlp"):
                    results[(policy_name, net)] = bench_one(
                        env_name, policy_name, net, k, n_envs,
                        rollout_len)
            for net in ("conv", "mlp"):
                emit("pixel_throughput_q_speedup",
                     f"{env_name}/k{k}/{net}",
                     fxp8_vs_fp32=round(results[("fxp8", net)]
                                        / results[("fp32", net)], 2))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--n-envs", type=int, default=0)
    ap.add_argument("--rollout-len", type=int, default=0)
    ap.add_argument("--envs", default=",".join(PIXEL_ENVS),
                    help="comma-separated subset of the pixel envs")
    ap.add_argument("--stacks", default="1,4",
                    help="comma-separated frame_stack depths")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None,
                    help="write the emit rows as JSON (CI gate input)")
    args = ap.parse_args(argv)
    run(fast=not args.full, n_envs=args.n_envs,
        rollout_len=args.rollout_len, envs=args.envs.split(","),
        stacks=[int(s) for s in args.stacks.split(",")])
    if args.csv:
        from benchmarks.common import dump_csv
        dump_csv(args.csv)
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
