"""Policy-serving bench — the deployment half of the paper's claim.

    PYTHONPATH=src:. python -m benchmarks.bench_serve_policy \
        [--episodes N] [--slots N] [--bucket N] [--json out.json]

Four policy legs (dqn/qrdqn on CartPole, ddpg on Pendulum — MLP torso
at width 256, where weight bytes dominate the fp32 bias/scale
overhead — and conv dqn on the Catch pixels) each served at three
precision points: fp32, w8 (int8 QTensor weights, the fxp8 activation
grid) and w4 (int4 weights, two codes per byte when stored).  Every
action flows through the micro-batching engine's pad-to-bucket path,
so the numbers are the production-serving numbers: actions/s,
p50/p99 per-request latency, and the packed model footprint.

The compression columns are machine-independent and asserted in-bench:
w8 must store at <= 0.27x of fp32 and w4 at <= 0.14x, the int8/int4
deployment points of the paper's compression claim (the slack over the
ideal 0.25x/0.125x is the fp32 biases and per-channel scales).
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit
from repro.rl.inference import make_value_agent
from repro.serve import PolicyServer, ServedPolicy, serve_episodes
from repro.serve.loader import PRECISIONS

# (algo, net, env, torso width override).  None keeps the net default:
# the conv stem's weight tensors already dwarf its bias/scale overhead.
LEGS = (
    ("dqn", "mlp", "cartpole", 256),
    ("qrdqn", "mlp", "cartpole", 256),
    ("ddpg", "mlp", "pendulum", 256),
    ("dqn", "conv", "catch", None),
)
# machine-independent storage bounds the bench enforces
COMPRESSION_BOUNDS = {"w8": 0.27, "w4": 0.14}


def build_policy(algo: str, net: str, env_name: str,
                 hidden, frame_stack: int = 2,
                 seed: int = 0) -> ServedPolicy:
    from repro.rl.inference import build_env
    k = frame_stack if net == "conv" else 1
    env = build_env(env_name, net, k)
    agent = make_value_agent(algo, env.spec, key=jax.random.PRNGKey(seed),
                             net=net, hidden=hidden)
    return ServedPolicy.from_agent(agent, env_name, net=net,
                                   frame_stack=k)


def run(fast: bool = True, episodes: int = 0, slots: int = 0,
        bucket: int = 0):
    episodes = episodes or (16 if fast else 200)
    slots = slots or (32 if fast else 128)
    bucket = bucket or (16 if fast else 64)
    mib = 1024 * 1024
    for algo, net, env_name, hidden in LEGS:
        policy = build_policy(algo, net, env_name, hidden)
        for prec in sorted(PRECISIONS):
            server = PolicyServer(policy, precision=prec,
                                  mode="greedy", max_bucket=bucket)
            st = serve_episodes(server, episodes, n_slots=slots,
                                seed=0)
            s = st.server
            bound = COMPRESSION_BOUNDS.get(prec)
            if bound is not None and s["compression"] > bound:
                raise AssertionError(
                    f"{algo}/{net}/{env_name} at {prec}: stored model "
                    f"is {s['compression']:.3f}x of fp32, above the "
                    f"{bound}x bound — the packed payload grew")
            emit("serve_policy", f"{algo}_{net}_{env_name}/{prec}",
                 algo=algo, net=net, env=env_name,
                 episodes=st.episodes, slots=slots, bucket=bucket,
                 actions_per_s=round(s["actions_per_s"]),
                 p50_ms=round(s["p50_ms"], 4),
                 p99_ms=round(s["p99_ms"], 4),
                 model_mib=round(s["model_bytes"] / mib, 4),
                 model_fp32_mib=round(s["model_fp32_bytes"] / mib, 4),
                 compression=round(s["compression"], 4),
                 jit_programs=int(s["jit_programs"]),
                 # wide per-row budget: sub-ms CPU dispatch latencies
                 # are noisy across runner classes; a real regression
                 # (e.g. losing the int8 kernel path) is far larger
                 slowdown_tol=3.0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--episodes", type=int, default=0)
    ap.add_argument("--slots", type=int, default=0)
    ap.add_argument("--bucket", type=int, default=0)
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None,
                    help="write the emit rows as JSON (CI gate input)")
    args = ap.parse_args(argv)
    run(fast=not args.full, episodes=args.episodes, slots=args.slots,
        bucket=args.bucket)
    if args.csv:
        from benchmarks.common import dump_csv
        dump_csv(args.csv)
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
