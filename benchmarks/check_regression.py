"""Benchmark regression gate: diff a fresh ``--json`` emit log against
the committed baseline.

    PYTHONPATH=src:. python -m benchmarks.check_regression \
        --current bench.json [bench2.json ...] \
        --baseline benchmarks/baselines/ci_cpu.json \
        [--max-slowdown 2.0] [--max-sync-growth 1.05] [--update]

Rows are keyed by ``(table, name)``.  The gate is deliberately
*generous* on timing — CI runners vary wildly, so only a >
``max-slowdown``x drop in any rate field fails (a baseline row may
override its own budget via a ``slowdown_tol`` field — micro-op
benches need a wider one) — but *tight* on
``sync_mib``: the int8 weight-sync payload is machine-independent, so
any growth beyond ``max-sync-growth``x (float slack) means the packed
sync actually got bigger and fails.  New rows (new benches/legs) pass
with a note; rows that *disappear* from the current run fail, so a
silently-dropped bench leg can't hide a regression.

``--update`` rewrites the baseline from the current rows instead of
checking (run it locally when a change legitimately shifts the
numbers, and commit the result).
"""
from __future__ import annotations

import argparse
import json
import sys

# higher is better, noisy (a row is only checked for the rate fields
# it actually carries — e.g. the replay bench emits adds/samples/
# updates rates, the throughput benches emit steps_per_s, the serving
# bench actions_per_s, and the reward-parity bench its returns: a
# return that drops below base/tol means a training path collapsed.
# Negative-return envs (pendulum) skip the check via the base > 0
# guard — a ratio gate is meaningless across zero)
RATE_FIELDS = ("steps_per_s", "adds_per_s", "samples_per_s",
               "updates_per_s", "actions_per_s", "convs_per_s",
               "gmacs_per_s", "fp32_return", "q8_return")
# lower is better, deterministic: packed payload bytes are machine-
# independent, so growth is exact — sync_mib is the actor-fleet weight
# sync, model_mib the served (int8/int4-packed) policy footprint
PAYLOAD_FIELDS = ("sync_mib", "model_mib")


def _load_rows(paths):
    rows = {}
    for path in paths:
        with open(path) as f:
            data = json.load(f)
        for row in data["rows"]:
            rows[(row["table"], row["name"])] = row
    return rows


def check(current: dict, baseline: dict, max_slowdown: float,
          max_sync_growth: float):
    failures, notes = [], []
    for key, base_row in sorted(baseline.items()):
        cur_row = current.get(key)
        if cur_row is None:
            failures.append(f"{key[0]}/{key[1]}: row missing from the "
                            "current run (bench leg dropped?)")
            continue
        # a row can carry its own slowdown budget: micro-op benches
        # (e.g. the sub-ms replay ops, dominated by dispatch overhead)
        # are far noisier than the steps/s sweeps, but their
        # algorithmic regressions are orders of magnitude — a wide
        # per-row tolerance still catches O(log n) -> O(n)
        tol = float(base_row.get("slowdown_tol", max_slowdown))
        for f in RATE_FIELDS:
            if f not in base_row:
                continue
            base, cur = float(base_row[f]), float(cur_row.get(f, 0.0))
            if base > 0 and cur < base / tol:
                failures.append(
                    f"{key[0]}/{key[1]}: {f} {cur:.0f} is more than "
                    f"{tol:.1f}x below baseline {base:.0f}")
        for f in PAYLOAD_FIELDS:
            if f not in base_row:
                continue
            if f not in cur_row:
                # a dropped field must not skip the exact payload check
                failures.append(f"{key[0]}/{key[1]}: {f} missing from "
                                "the current row")
                continue
            base, cur = float(base_row[f]), float(cur_row[f])
            if cur > base * max_sync_growth:
                failures.append(
                    f"{key[0]}/{key[1]}: {f} grew {base:.4f} -> "
                    f"{cur:.4f} MiB (payload regressions are exact)")
    for key in sorted(set(current) - set(baseline)):
        notes.append(f"{key[0]}/{key[1]}: new row (not in baseline)")
    return failures, notes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", nargs="+", required=True,
                    help="one or more --json emit logs from this run")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/ci_cpu.json")
    ap.add_argument("--max-slowdown", type=float, default=2.0,
                    help="fail when steps_per_s drops by more than this "
                         "factor (generous: CI runners are noisy)")
    ap.add_argument("--max-sync-growth", type=float, default=1.05,
                    help="fail when sync_mib grows by more than this "
                         "factor (payloads are machine-independent)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current rows")
    args = ap.parse_args(argv)

    current = _load_rows(args.current)
    if args.update:
        rows = [current[k] for k in sorted(current)]
        with open(args.baseline, "w") as f:
            json.dump({"rows": rows}, f, indent=1, sort_keys=True)
        print(f"baseline updated: {len(rows)} rows -> {args.baseline}")
        return 0

    baseline = _load_rows([args.baseline])
    failures, notes = check(current, baseline, args.max_slowdown,
                            args.max_sync_growth)
    for n in notes:
        print(f"NOTE  {n}")
    for f in failures:
        print(f"FAIL  {f}")
    if failures:
        print(f"\nbenchmark regression gate: {len(failures)} failure(s) "
              f"vs {args.baseline}")
        return 1
    print(f"benchmark regression gate: {len(baseline)} row(s) OK "
          f"(slowdown tol {args.max_slowdown}x, sync tol "
          f"{args.max_sync_growth}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
