"""§Roofline table emitter: reads the dry-run JSON (if present) and
prints the per-cell roofline terms as a markdown table; used by
EXPERIMENTS.md.  The dry-run itself runs out-of-process (it needs the
512-device XLA flag before jax init)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

JSON_PATHS = ["dryrun_single_pod.json", "/root/repo/dryrun_single_pod.json"]


def run():
    path = next((p for p in JSON_PATHS if os.path.exists(p)), None)
    if path is None:
        emit("roofline", "missing",
             note="run: PYTHONPATH=src python -m repro.launch.dryrun "
                  "--arch all --shape all --json dryrun_single_pod.json")
        return
    with open(path) as f:
        results = json.load(f)
    ok = skip = 0
    for r in results:
        if r.get("status") != "ok":
            skip += 1
            continue
        ok += 1
        f_ = r["roofline"]
        emit("roofline", f"{r['arch']}x{r['shape']}",
             bound=f_["bound"],
             t_compute=f"{f_['t_compute']:.2e}",
             t_memory=f"{f_['t_memory']:.2e}",
             t_collective=f"{f_['t_collective']:.2e}",
             mfu_at_roofline=f"{100 * f_['mfu_at_roofline']:.1f}%",
             hbm_gib=round(r["memory"]["total_bytes"] / 2**30, 1))
    emit("roofline", "summary", ok=ok, skipped_or_failed=skip)
