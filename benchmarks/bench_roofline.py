"""§Roofline table emitter: reads the dry-run JSON (if present) and
prints the per-cell roofline terms as a markdown table; used by
EXPERIMENTS.md.  The dry-run itself runs out-of-process (it needs the
512-device XLA flag before jax init).

Also measures one *live* integer-op row: the Q-Conv stem contraction
(kernels/qconv taps path) against the fake-quant XLA conv on the same
shape, in effective GMAC/s — the measured counterpart of the int-op
roofline term, gated by ``check_regression`` (``gmacs_per_s``).

Standalone:

    PYTHONPATH=src:. python -m benchmarks.bench_roofline [--json out]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import emit, timeit

JSON_PATHS = ["dryrun_single_pod.json", "/root/repo/dryrun_single_pod.json"]

# the keydoor/k4 training stem's first block, padded batch: the
# MAC-heaviest conv CI actually runs
QCONV_SHAPE = dict(b=64, h=32, w=32, c=12, n=16, k=3, stride=2)


def run_qconv_int_ops():
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core.policy import get_policy
    from repro.nn.conv import conv2d_apply, conv2d_init
    from repro.nn.module import unbox

    s = QCONV_SHAPE
    x = jax.random.normal(jax.random.PRNGKey(0),
                          (s["b"], s["h"], s["w"], s["c"]))
    p = unbox(conv2d_init(jax.random.PRNGKey(1), s["c"], s["n"],
                          s["k"]))
    ho = -(-s["h"] // s["stride"])
    wo = -(-s["w"] // s["stride"])
    macs = s["b"] * ho * wo * s["k"] * s["k"] * s["c"] * s["n"]
    fxp8 = get_policy("fxp8")
    for variant, backend in (("qconv_int8", "xla"),
                             ("qconv_fakequant", "ref")):
        pol = dataclasses.replace(fxp8, backend=backend)
        fn = jax.jit(lambda xx, pol=pol: conv2d_apply(
            p, xx, stride=s["stride"], policy=pol))
        sec = timeit(fn, x, warmup=2, iters=20)
        emit("roofline", variant,
             bound="live-int-op", backend=jax.default_backend(),
             gmacs_per_s=round(macs / sec / 1e9, 2),
             us_per_conv=round(sec * 1e6, 1),
             shape="x".join(str(v) for v in s.values()))


def run():
    run_qconv_int_ops()
    path = next((p for p in JSON_PATHS if os.path.exists(p)), None)
    if path is None:
        emit("roofline", "missing",
             note="run: PYTHONPATH=src python -m repro.launch.dryrun "
                  "--arch all --shape all --json dryrun_single_pod.json")
        return
    with open(path) as f:
        results = json.load(f)
    ok = skip = 0
    for r in results:
        if r.get("status") != "ok":
            skip += 1
            continue
        ok += 1
        f_ = r["roofline"]
        emit("roofline", f"{r['arch']}x{r['shape']}",
             bound=f_["bound"],
             t_compute=f"{f_['t_compute']:.2e}",
             t_memory=f"{f_['t_memory']:.2e}",
             t_collective=f"{f_['t_collective']:.2e}",
             mfu_at_roofline=f"{100 * f_['mfu_at_roofline']:.1f}%",
             hbm_gib=round(r["memory"]["total_bytes"] / 2**30, 1))
    emit("roofline", "summary", ok=ok, skipped_or_failed=skip)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None,
                    help="write the emit rows as JSON (CI gate input)")
    args = ap.parse_args(argv)
    run()
    if args.csv:
        from benchmarks.common import dump_csv
        dump_csv(args.csv)
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
