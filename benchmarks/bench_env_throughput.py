"""Env throughput sweep: every registered env x precision x devices.

For each registered environment, roll the actor fleet through
``collect_sharded`` on a host mesh of 1..N devices with the actor
policy at FP32 vs FxP8 (int8 weights + activations), reporting
env-steps/s and the int8 weight-sync payload (MiB) — the fleet-level
view of the paper's throughput claims, extending bench_rewards.py
beyond cartpole.

The ``value_throughput`` rows time the full sharded *off-policy* loop
(qrdqn collect + replay shards + psum learner) end to end at each
device count, in both weight-sync modes: ``lockstep`` fences the
dispatch stream every iteration, ``doublebuf`` fetches one version
behind and lets the next collect overlap the in-flight learner update.
``value_sync`` reports the doublebuf/lockstep speedup per device
count.

Standalone (8 forced host devices):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_env_throughput

or via the orchestrator: ``python -m benchmarks.run --only env_throughput``.
"""
from __future__ import annotations

import argparse
import math

import jax

from benchmarks.common import emit, timeit
from repro.core.policy import get_policy
from repro.launch.mesh import describe, make_host_mesh
from repro.nn.module import unbox
from repro.rl import init_envs
from repro.rl.actor_learner import collect_sharded, pack_weights, sync_bytes
from repro.rl.envs import make, registered
from repro.rl.envs.spaces import head_dim
from repro.rl.envs.wrappers import ensure_vector_obs
from repro.rl.nets import mlp_ac_apply, mlp_ac_init


def _device_counts():
    """1, the full host, and powers of two in between."""
    n = len(jax.devices())
    counts, c = [], 1
    while c < n:
        counts.append(c)
        c *= 2
    counts.append(n)
    return counts


def bench_one(env_name: str, policy_name: str, n_dev: int,
              n_envs: int, rollout_len: int) -> float:
    env = ensure_vector_obs(make(env_name))
    policy = get_policy(policy_name) if policy_name != "fp32" else None
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), env.obs_shape[0],
                               head_dim(env.action_space)))
    packed = pack_weights(params, 8 if policy else 32)
    payload, fp32_eq = sync_bytes(packed)
    mesh = make_host_mesh(n_dev)
    est, obs = init_envs(env, jax.random.PRNGKey(1), n_envs, mesh=mesh)

    fn = jax.jit(lambda packed, key, est, obs: collect_sharded(
        packed, env, mlp_ac_apply, policy, key, est, obs, rollout_len,
        mesh))
    sec = timeit(fn, packed, jax.random.PRNGKey(2), est, obs,
                 warmup=1, iters=5)
    steps_per_s = n_envs * rollout_len / sec
    emit("env_throughput", f"{env_name}/{policy_name}/{n_dev}dev",
         env=env_name, policy=policy_name, devices=n_dev,
         n_envs=n_envs, rollout_len=rollout_len,
         steps_per_s=int(steps_per_s),
         sync_mib=round(payload / 2**20, 4),
         sync_fp32_mib=round(fp32_eq / 2**20, 4))
    return steps_per_s


def bench_value_one(env_name: str, algo: str, sync: str, n_dev: int,
                    n_envs: int, rollout_len: int,
                    iters: int = 6) -> float:
    """Time the sharded value loop (FleetSync fetch + collect + learn,
    barrier included in lockstep mode) end to end, compile excluded."""
    import time as _time

    from repro.rl.actor_learner import FleetSync
    from repro.rl.trainer import ValueTrainer

    tr = ValueTrainer(algo, env_name, iters=iters, n_envs=n_envs,
                      rollout_len=rollout_len, verbose=False,
                      replay_capacity=8192, learn_start=64,
                      mesh_kind="host", mesh_devices=n_dev, sync=sync)
    state = tr.init_state()
    iteration = tr.build_iteration()
    fleet = FleetSync(tr.n_slots, max_lag=tr.max_lag)
    payload = 0

    def one(state, g):
        nonlocal payload
        fleet.push(tr.pack(state))
        stale = fleet.fetch(tr.fetch_lag)
        payload, _ = sync_bytes(stale)
        sub = jax.random.fold_in(tr.key, g)
        state, ret, _ = tr.step(iteration, state, stale, sub, g, None,
                                fleet.alive())
        if tr.barrier:
            jax.block_until_ready((state, ret))
        return state

    # warmup must reach the steady-state trace: the first calls see
    # eager-init avals (and, at fetch lag 1, a one-iteration-old packed
    # tree), each a distinct jit entry — 3 iterations cover them all
    for g in range(3):
        state = one(state, g)
    jax.block_until_ready(state)
    t0 = _time.perf_counter()
    for g in range(3, 3 + iters):
        state = one(state, g)
    jax.block_until_ready(state)
    sec = (_time.perf_counter() - t0) / iters
    steps_per_s = n_envs * rollout_len / sec
    emit("value_throughput", f"{env_name}/{algo}/{sync}/{n_dev}dev",
         env=env_name, algo=algo, sync=sync, devices=n_dev,
         n_envs=n_envs, rollout_len=rollout_len,
         steps_per_s=int(steps_per_s),
         sync_mib=round(payload / 2**20, 4))
    return steps_per_s


def run(fast: bool = True, n_envs: int = 0, rollout_len: int = 0,
        device_counts=None):
    counts = list(device_counts or _device_counts())
    n_envs = n_envs or (512 if fast else 4096)
    rollout_len = rollout_len or (64 if fast else 256)
    # every leg of the sweep needs n_envs % n_dev == 0
    lcm = math.lcm(*counts)
    n_envs = max(lcm, n_envs - n_envs % lcm)
    print(f"{describe(make_host_mesh())}; sweeping devices={counts}, "
          f"n_envs={n_envs}, rollout_len={rollout_len}")
    for env_name in registered():
        for policy_name in ("fp32", "fxp8"):
            results = {n_dev: bench_one(env_name, policy_name, n_dev,
                                        n_envs, rollout_len)
                       for n_dev in counts}
            if 1 in results:             # only meaningful vs 1 device
                for n_dev in counts:
                    if n_dev != 1:
                        emit("env_throughput_scaling",
                             f"{env_name}/{policy_name}/{n_dev}dev",
                             speedup_vs_1dev=round(
                                 results[n_dev] / results[1], 2))
    # the sharded value loop, lock-step vs double-buffered weight sync
    for env_name, algo in (("cartpole", "qrdqn"),):
        for n_dev in counts:
            ls = bench_value_one(env_name, algo, "lockstep", n_dev,
                                 n_envs, rollout_len)
            db = bench_value_one(env_name, algo, "doublebuf", n_dev,
                                 n_envs, rollout_len)
            emit("value_sync", f"{env_name}/{algo}/{n_dev}dev",
                 devices=n_dev, doublebuf_speedup=round(db / ls, 2))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--n-envs", type=int, default=0)
    ap.add_argument("--rollout-len", type=int, default=0)
    ap.add_argument("--device-counts", default=None,
                    help="comma-separated, e.g. 1,8 (default: powers of "
                         "two up to the host device count)")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None,
                    help="write the emit rows as JSON (CI gate input)")
    args = ap.parse_args(argv)
    counts = ([int(c) for c in args.device_counts.split(",")]
              if args.device_counts else None)
    run(fast=not args.full, n_envs=args.n_envs,
        rollout_len=args.rollout_len, device_counts=counts)
    if args.csv:
        from benchmarks.common import dump_csv
        dump_csv(args.csv)
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
