"""Paper Fig. 3a analogue — reward parity: quantized vs FP32 actors.

PPO (the paper's training algorithm) and A2C on pure-JAX CartPole, plus
the off-policy value-based family — Double-DQN and QR-DQN on CartPole,
TD3-style DDPG on the continuous Pendulum — with the behaviour actor's
rollout policy at FP32 vs FxP8 (int8 weights AND activations + V-ACT
activations).  The claim under test: Q8 actors reach the same reward,
enabling the throughput/energy savings for free.

Value-based runs train through :func:`repro.launch.rl_train.value_train`
(truncation-aware n-step replay, polyak targets) and report a greedy
evaluation under the same actor precision.

Budgets are CPU-friendly; the criterion is parity (Q8 close to FP32 at
equal step budget), not absolute SOTA returns.
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit
from repro.core.policy import get_policy
from repro.launch.rl_train import value_eval, value_train
from repro.nn.module import unbox
from repro.optim import AdamWConfig, adamw_init, adamw_update, constant
from repro.rl import PPOConfig, batch_from_traj, init_envs, rollout
from repro.rl.actor_learner import pack_weights, unpack_weights
from repro.rl.envs import make
from repro.rl.nets import mlp_ac_apply, mlp_ac_init
from repro.rl.ppo import a2c_loss, minibatch_epochs, ppo_loss
from repro.rl.rollout import episode_returns

ENV = make("cartpole")
N_ENVS, T = 32, 128


def train_pg(algo: str, actor_policy, iters: int, seed: int = 0):
    """PPO/A2C with (optionally quantized) rollout actors."""
    key = jax.random.PRNGKey(seed)
    params = unbox(mlp_ac_init(key, 4, ENV.spec.n_actions))
    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)
    pcfg = PPOConfig(epochs=4 if algo == "ppo" else 1,
                     minibatches=4 if algo == "ppo" else 1)
    sched = constant(3e-3)
    loss_fn = ppo_loss if algo == "ppo" else a2c_loss
    est, obs = init_envs(ENV, jax.random.PRNGKey(seed + 1), N_ENVS)
    learner_apply = lambda p, o: mlp_ac_apply(p, o, None)

    @jax.jit
    def it(params, opt, est, obs, key):
        k1, k2 = jax.random.split(key)
        actor_params = unpack_weights(pack_weights(
            params, 8 if actor_policy else 32))
        actor_apply = lambda p, o: mlp_ac_apply(p, o, actor_policy)
        res = rollout(actor_params, ENV, actor_apply, k1, est, obs, T)
        batch = batch_from_traj(
            res.traj, res.last_value, pcfg,
            value_fn=lambda o: learner_apply(params, o)[1])

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        params, opt, _ = minibatch_epochs(k2, params, opt, batch,
                                          learner_apply, pcfg,
                                          opt_step, loss_fn=loss_fn)
        ret, _ = episode_returns(res.traj)
        return params, opt, res.final_env, res.final_obs, ret

    rets = []
    for i in range(iters):
        key, sub = jax.random.split(key)
        params, opt, est, obs, ret = it(params, opt, est, obs, sub)
        rets.append(float(ret))
    tail = rets[-5:]
    return sum(tail) / len(tail), rets


def train_value(algo: str, env_name: str, actor_policy_name, iters: int,
                seed: int = 0):
    """Train via the value subsystem, report a greedy eval return
    under the same actor precision the fleet would deploy with."""
    params, _ = value_train(algo, env_name, iters=iters, n_envs=N_ENVS,
                            rollout_len=8, actor_policy=actor_policy_name,
                            seed=seed, verbose=False)
    ret, _ = value_eval(algo, env_name, params, n_envs=16,
                        actor_policy=actor_policy_name, seed=seed)
    return ret


def run(fast: bool = True):
    iters = 30 if fast else 80
    fxp8 = get_policy("fxp8")
    for algo in ("ppo", "a2c"):
        fp32_ret, _ = train_pg(algo, None, iters)
        q8_ret, _ = train_pg(algo, fxp8, iters)
        emit("rewards", f"{algo}_cartpole",
             fp32_return=round(fp32_ret, 1),
             q8_return=round(q8_ret, 1),
             parity=round(q8_ret / max(fp32_ret, 1e-9), 2),
             # returns at fast budgets are seeded but land on a noisy
             # part of the learning curve; the gate only needs to catch
             # a collapse (quantized actors stop learning), not jitter
             slowdown_tol=2.5)
    value_iters = 200 if fast else 600
    for algo, env_name in (("dqn", "cartpole"), ("qrdqn", "cartpole"),
                           ("ddpg", "pendulum")):
        fp32_ret = train_value(algo, env_name, None, value_iters)
        q8_ret = train_value(algo, env_name, "fxp8", value_iters)
        emit("rewards", f"{algo}_{env_name}",
             fp32_return=round(fp32_ret, 1),
             q8_return=round(q8_ret, 1),
             gap=round(q8_ret - fp32_ret, 1),
             slowdown_tol=2.5)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training budgets")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None,
                    help="write the emit rows as JSON (CI gate input)")
    args = ap.parse_args(argv)
    run(fast=not args.full)
    if args.csv:
        from benchmarks.common import dump_csv
        dump_csv(args.csv)
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
