"""Paper Fig. 3a analogue — reward parity: quantized vs FP32 actors.

PPO (the paper's training algorithm), A2C and DQN on pure-JAX CartPole
with the actor's rollout policy at FP32 vs FxP8 (int8 weights AND
activations + V-ACT activations).  The claim under test: Q8 actors
reach the same reward, enabling the throughput/energy savings for free.

Budgets are CPU-friendly; the criterion is parity (Q8 within ~15% of
FP32 at equal step budget), not absolute SOTA returns.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.policy import get_policy
from repro.nn.module import unbox
from repro.optim import AdamWConfig, adamw_init, adamw_update, constant
from repro.rl import PPOConfig, batch_from_traj, init_envs, rollout
from repro.rl.actor_learner import pack_weights, unpack_weights
from repro.rl.dqn import (DQNConfig, dqn_loss, egreedy, epsilon,
                          replay_add, replay_init, replay_sample)
from repro.rl.envs import make
from repro.rl.nets import (mlp_ac_apply, mlp_ac_init, mlp_q_apply,
                           mlp_q_init)
from repro.rl.ppo import a2c_loss, minibatch_epochs, ppo_loss
from repro.rl.rollout import episode_returns

ENV = make("cartpole")
N_ENVS, T = 32, 128


def train_pg(algo: str, actor_policy, iters: int, seed: int = 0):
    """PPO/A2C with (optionally quantized) rollout actors."""
    key = jax.random.PRNGKey(seed)
    params = unbox(mlp_ac_init(key, 4, ENV.spec.n_actions))
    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)
    pcfg = PPOConfig(epochs=4 if algo == "ppo" else 1,
                     minibatches=4 if algo == "ppo" else 1)
    sched = constant(3e-3)
    loss_fn = ppo_loss if algo == "ppo" else a2c_loss
    est, obs = init_envs(ENV, jax.random.PRNGKey(seed + 1), N_ENVS)
    learner_apply = lambda p, o: mlp_ac_apply(p, o, None)

    @jax.jit
    def it(params, opt, est, obs, key):
        k1, k2 = jax.random.split(key)
        actor_params = unpack_weights(pack_weights(
            params, 8 if actor_policy else 32))
        actor_apply = lambda p, o: mlp_ac_apply(p, o, actor_policy)
        res = rollout(actor_params, ENV, actor_apply, k1, est, obs, T)
        batch = batch_from_traj(res.traj, res.last_value, pcfg)

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        params, opt, _ = minibatch_epochs(k2, params, opt, batch,
                                          learner_apply, pcfg,
                                          opt_step, loss_fn=loss_fn)
        ret, _ = episode_returns(res.traj)
        return params, opt, res.final_env, res.final_obs, ret

    rets = []
    for i in range(iters):
        key, sub = jax.random.split(key)
        params, opt, est, obs, ret = it(params, opt, est, obs, sub)
        rets.append(float(ret))
    tail = rets[-5:]
    return sum(tail) / len(tail), rets


def train_dqn(actor_policy, iters: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = unbox(mlp_q_init(key, 4, ENV.spec.n_actions))
    target = params
    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.0)
    cfg = DQNConfig(eps_decay_steps=iters // 2)
    sched = constant(1e-3)
    buf = replay_init(8192, (4,))
    est, obs = init_envs(ENV, jax.random.PRNGKey(seed + 1), N_ENVS)
    returns, acc, done_cnt = [], jnp.zeros(N_ENVS), 0

    @jax.jit
    def step(params, target, opt, buf, est, obs, i, key):
        k1, k2 = jax.random.split(key)
        ap = unpack_weights(pack_weights(params,
                                         8 if actor_policy else 32))
        q = mlp_q_apply(ap, obs, actor_policy)
        a = egreedy(k1, q, epsilon(i, cfg))
        est2, obs2, r, d = jax.vmap(ENV.step)(est, a)
        buf = replay_add(buf, obs, a, r, obs2, d)
        batch = replay_sample(buf, k2, cfg.batch_size)
        g = jax.grad(dqn_loss)(params, target,
                               lambda p, o: mlp_q_apply(p, o, None),
                               batch, cfg)
        params, opt, _ = adamw_update(g, opt, params, sched, ocfg)
        return params, opt, buf, est2, obs2, r, d

    ep_returns = []
    for i in range(iters):
        key, sub = jax.random.split(key)
        params, opt, buf, est, obs, r, d = step(
            params, target, opt, buf, est, obs, jnp.asarray(i), sub)
        acc = acc + r
        finished = acc * d.astype(jnp.float32)
        n = int(d.sum())
        if n:
            ep_returns.extend([float(x) for x in finished[d] if x > 0])
        acc = acc * (1.0 - d.astype(jnp.float32))
        if i % cfg.target_update_every == 0:
            target = params
    tail = ep_returns[-20:] or [0.0]
    return sum(tail) / len(tail), ep_returns


def run(fast: bool = True):
    iters = 30 if fast else 80
    fxp8 = get_policy("fxp8")
    for algo in ("ppo", "a2c"):
        fp32_ret, _ = train_pg(algo, None, iters)
        q8_ret, _ = train_pg(algo, fxp8, iters)
        emit("rewards", f"{algo}_cartpole",
             fp32_return=round(fp32_ret, 1),
             q8_return=round(q8_ret, 1),
             parity=round(q8_ret / max(fp32_ret, 1e-9), 2))
    dqn_iters = 1500 if fast else 4000
    fp32_ret, _ = train_dqn(None, dqn_iters)
    q8_ret, _ = train_dqn(fxp8, dqn_iters)
    emit("rewards", "dqn_cartpole",
         fp32_return=round(fp32_ret, 1),
         q8_return=round(q8_ret, 1),
         parity=round(q8_ret / max(fp32_ret, 1e-9), 2))
