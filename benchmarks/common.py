"""Shared benchmark plumbing: timing, CSV emit, derived metrics."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

ROWS: List[Dict] = []


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock seconds per call (jit'd fn, blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(table: str, name: str, **fields):
    row = {"table": table, "name": name, **fields}
    ROWS.append(row)
    kv = "  ".join(f"{k}={v}" for k, v in fields.items())
    print(f"[{table}] {name}: {kv}")


def dump_csv(path: str):
    import csv
    keys: List[str] = []
    for r in ROWS:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(ROWS)
    print(f"wrote {len(ROWS)} rows -> {path}")


def dump_json(path: str):
    """Machine-readable emit log — what the CI regression gate diffs
    against the committed baseline (benchmarks/check_regression.py)."""
    import json
    import platform
    import jaxlib
    out = {
        "meta": {
            "python": platform.python_version(),
            "machine": platform.machine(),
            # terse and hostname-free, so baselines diff cleanly
            # across machines of the same class
            "platform": platform.platform(terse=True),
            "jax": jax.__version__,
            "jaxlib": jaxlib.__version__,
            "devices": len(jax.devices()),
            "backend": jax.default_backend(),
        },
        "rows": ROWS,
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"wrote {len(ROWS)} rows -> {path}")


# energy proxy: on modern silicon, data movement dominates; a standard
# first-order model charges pJ per byte moved between levels and pJ per
# MAC by operand width (Horowitz ISSCC'14 scaled to ~7nm-class nodes).
PJ_PER_BYTE_HBM = 7.0
PJ_PER_MAC = {8: 0.2, 16: 0.8, 32: 3.1}


def energy_proxy_mj(macs: float, bits: int, hbm_bytes: float) -> float:
    pj = macs * PJ_PER_MAC[bits] + hbm_bytes * PJ_PER_BYTE_HBM
    return pj * 1e-9
