"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only qmac,vact,...]
                                            [--full] [--csv out.csv]

  qmac        Table II/III  Q-MAC precision->throughput/energy scaling
  vact        Table IV      V-ACT CORDIC accuracy/latency per AF+precision
  arch        Table V       E2HRL agent FPS/energy per precision + sync
  rewards     Fig. 3a       FP32 vs Q8 reward parity (PPO/A2C +
                            DQN/QR-DQN/DDPG via the value subsystem)
  env_throughput  Fig. 2    sharded-fleet env-steps/s: every registered
                            env x fp32/fxp8 x device count + sync MiB
  pixel       Sec. III      pixel-pipeline env-steps/s: catch/keydoor x
                            frame_stack x fp32/fxp8 x conv/mlp net
  replay      §Replay       replay backends: capacity x batch x
                            uniform/per — adds/s, samples/s,
                            priority-updates/s
  serve       §Serving      batched policy serving: algo x net x
                            fp32/w8/w4 — actions/s, p50/p99 latency,
                            packed model MiB
  lm          Sec. IV       the fabric generalized to LM train/serve
  roofline    §Roofline     dry-run derived terms (needs dryrun JSON)
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (bench_arch, bench_env_throughput, bench_lm,
                        bench_pixel_throughput, bench_qmac,
                        bench_replay, bench_rewards, bench_roofline,
                        bench_serve_policy, bench_vact)
from benchmarks.common import dump_csv

SUITES = {
    "qmac": lambda full: bench_qmac.run(),
    "vact": lambda full: bench_vact.run(),
    "arch": lambda full: bench_arch.run(),
    "rewards": lambda full: bench_rewards.run(fast=not full),
    "env_throughput": lambda full: bench_env_throughput.run(fast=not full),
    "pixel": lambda full: bench_pixel_throughput.run(fast=not full),
    "replay": lambda full: bench_replay.run(fast=not full),
    "serve": lambda full: bench_serve_policy.run(fast=not full),
    "lm": lambda full: bench_lm.run(),
    "roofline": lambda full: bench_roofline.run(),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of "
                         f"{sorted(SUITES)}")
    ap.add_argument("--full", action="store_true",
                    help="longer reward-parity budgets")
    ap.add_argument("--csv", default="bench_results.csv")
    args = ap.parse_args(argv)

    names = (args.only.split(",") if args.only else list(SUITES))
    for name in names:
        t0 = time.time()
        print(f"\n===== bench: {name} =====")
        SUITES[name](args.full)
        print(f"===== {name} done in {time.time() - t0:.0f}s =====")
    if args.csv:
        dump_csv(args.csv)


if __name__ == "__main__":
    main()
