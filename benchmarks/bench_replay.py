"""Replay-subsystem throughput: capacity x batch x backend.

For each (capacity, batch) cell, time the three hot operations of both
``repro.rl.replay`` backends on a half-full buffer:

  * ``adds_per_s``     — circular insert of a ``batch``-sized chunk
                         (PER: + max-priority tree write);
  * ``samples_per_s``  — a ``batch``-sized draw (uniform randint vs
                         PER stratified sum-tree descent + IS weights);
  * ``updates_per_s``  — the PER priority write-back (O(batch log n)
                         leaf + ancestor refresh; the uniform backend
                         has no such op, so no row field).

The interesting number is the PER-over-uniform overhead: the sum tree
buys prioritized sampling for two O(log n) passes, and this bench is
the regression gate (benchmarks/check_regression.py) that keeps those
passes from quietly becoming O(n).

    PYTHONPATH=src:. python -m benchmarks.bench_replay [--json out.json]

or via the orchestrator: ``python -m benchmarks.run --only replay``.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.rl.replay import make_replay

OBS_DIM = 8          # cartpole-class vector observations


def _chunk(key, batch: int):
    ko, kr = jax.random.split(key)
    obs = jax.random.normal(ko, (batch, OBS_DIM))
    return (obs, jnp.zeros((batch,), jnp.int32),
            jax.random.normal(kr, (batch,)), obs + 1.0,
            jnp.full((batch,), 0.99))


def bench_one(kind: str, capacity: int, batch: int):
    rb = make_replay(kind, capacity, (OBS_DIM,))
    state = rb.init()
    # half-fill so sampling/updates hit a realistic valid prefix
    fill = _chunk(jax.random.PRNGKey(0), capacity // 2)
    state = jax.jit(rb.add)(state, *fill)

    add = jax.jit(rb.add)
    sample = jax.jit(lambda s, k: rb.sample(s, k, batch, min_size=1,
                                            beta=0.5))
    chunk = _chunk(jax.random.PRNGKey(1), batch)
    key = jax.random.PRNGKey(2)

    fields = dict(
        backend=kind, capacity=capacity, batch=batch,
        # sub-ms ops on throttled shared runners: medians drift up to
        # ~20x run to run, so the row carries its own coarse gate
        # budget — the gate is a catastrophic-regression net here
        # (e.g. an accidental per-item tree rebuild), not a 2x watchdog
        slowdown_tol=30.0,
        adds_per_s=int(batch / timeit(add, state, *chunk,
                                      warmup=2, iters=10)),
        samples_per_s=int(batch / timeit(sample, state, key,
                                         warmup=2, iters=10)),
    )
    if rb.prioritized:
        idx = jax.random.randint(jax.random.PRNGKey(3), (batch,), 0,
                                 capacity // 2)
        td = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (batch,)))
        update = jax.jit(rb.update)
        fields["updates_per_s"] = int(
            batch / timeit(update, state, idx, td, warmup=2, iters=10))
    emit("replay", f"{kind}/cap{capacity}/b{batch}", **fields)


def run(fast: bool = True, capacities=None, batches=None):
    capacities = capacities or ([2**14] if fast else [2**14, 2**17])
    batches = batches or [64, 256]
    for capacity in capacities:
        for batch in batches:
            for kind in ("uniform", "per"):
                bench_one(kind, capacity, batch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--capacities", default=None,
                    help="comma-separated, e.g. 16384,131072")
    ap.add_argument("--batches", default=None,
                    help="comma-separated, e.g. 64,256")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None,
                    help="write the emit rows as JSON (CI gate input)")
    args = ap.parse_args(argv)
    caps = ([int(c) for c in args.capacities.split(",")]
            if args.capacities else None)
    batches = ([int(b) for b in args.batches.split(",")]
               if args.batches else None)
    run(fast=not args.full, capacities=caps, batches=batches)
    if args.csv:
        from benchmarks.common import dump_csv
        dump_csv(args.csv)
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)


if __name__ == "__main__":
    main()
